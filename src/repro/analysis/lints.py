"""Program lints over walker censuses.

Two scopes:

* :func:`lint_backward_counts` — per-site backward probes. Checks the
  numerics contract (no f32 contraction inside a ``bwd_dtype="bfloat16"``
  region) and that no host callback hides in the backward.
* :func:`lint_step_counts` — whole jitted train/serve step programs.
  Adds the transfer check plus dead-code findings: contraction FLOPs
  buried in equations nothing demands (a forgotten aux output, a branch
  XLA can't DCE because of effects) and ``while`` loops the FLOPs bound
  cannot see through.

Dead FLOPs are a *warning*, not an error: ``jax.vjp`` probes legitimately
drag a dead forward half along, and step functions may keep debug
outputs on purpose. Callbacks and dtype leaks are errors — both violate
documented contracts (DESIGN.md: jitted steps never touch the host;
``bwd_dtype`` regions compute every contraction in bf16).
"""
from __future__ import annotations

from repro.analysis import jaxpr_walk
from repro.analysis.report import ERROR, INFO, Report, WARN
from repro.core.policy import SsPropPolicy


def lint_backward_counts(
    report: Report,
    site: str,
    counts: jaxpr_walk.Counts,
    policy: SsPropPolicy,
) -> None:
    """Dtype-leak + host-transfer lints on one backward probe."""
    if policy.bwd_dtype == "bfloat16":
        for c in counts.contractions:
            leaked = [d for d in c.operand_dtypes if d == "float32"]
            if leaked:
                report.add(
                    "dtype",
                    ERROR,
                    site,
                    f"f32 contraction inside bwd_dtype=bfloat16 region: "
                    f"{c.prim} operands {c.operand_dtypes} at {c.path}",
                    prim=c.prim,
                    operand_dtypes=list(c.operand_dtypes),
                    path=c.path,
                )
    for path in counts.callbacks:
        report.add(
            "transfer",
            ERROR,
            site,
            f"host callback inside jitted backward: {path}",
            path=path,
        )


def lint_step_counts(
    report: Report,
    name: str,
    counts: jaxpr_walk.Counts,
) -> None:
    """Transfer + dead-code + loop lints on one full jitted step."""
    for path in counts.callbacks:
        report.add(
            "transfer",
            ERROR,
            name,
            f"host callback inside jitted step: {path}",
            path=path,
        )
    if counts.dead_flops:
        report.add(
            "dead",
            WARN,
            name,
            f"{counts.dead_flops:,} contraction FLOPs in equations no "
            f"output demands ({counts.dead_eqns} dead eqns) — forgotten "
            "aux output or undead debug branch?",
            dead_flops=counts.dead_flops,
            dead_eqns=counts.dead_eqns,
        )
    elif counts.dead_eqns:
        report.add(
            "dead",
            INFO,
            name,
            f"{counts.dead_eqns} dead equations (no contraction FLOPs)",
            dead_eqns=counts.dead_eqns,
        )
    if counts.unbounded_loops:
        report.add(
            "dead",
            WARN,
            name,
            f"{counts.unbounded_loops} while loop(s): FLOPs bound counts "
            "one trip per loop",
            unbounded_loops=counts.unbounded_loops,
        )
