"""Static program auditor: jaxpr walks, lints, retrace budgets, Pallas checks.

``repro.analysis`` never executes model code — it traces (abstract
values only), walks the resulting ClosedJaxprs, and evaluates kernel
specs. Entry points:

* :mod:`~repro.analysis.savings` — honest-savings audit: jaxpr-measured
  backward FLOPs vs the analytic tables in ``core/flops.py``.
* :mod:`~repro.analysis.lints` — dtype-leak / host-transfer / dead-code
  lints over walker censuses.
* :mod:`~repro.analysis.retrace` — compiled-executable budgets for
  train programs and the serve engine.
* :mod:`~repro.analysis.pallas_check` — in-bounds, divisibility, VMEM
  and traffic checks over the kernel specs.
* ``launch/analyze.py`` — the CLI that runs all of it per config.
"""
from repro.analysis import jaxpr_walk, lints, pallas_check, retrace, savings
from repro.analysis.report import ERROR, INFO, WARN, Finding, Report

__all__ = [
    "ERROR",
    "INFO",
    "WARN",
    "Finding",
    "Report",
    "jaxpr_walk",
    "lints",
    "pallas_check",
    "retrace",
    "savings",
]
