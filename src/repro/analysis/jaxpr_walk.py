"""Recursive ClosedJaxpr walker: FLOPs bounds + program census.

The auditor's measurement layer. Walks a jaxpr the way XLA will run it
— recursing into ``pjit``/``scan``/``cond``/``while``/``remat``/custom-
vjp call bodies and into ``pallas_call`` kernel jaxprs — and produces:

  * **contraction FLOPs bounds** ``(flops_lo, flops_hi)``: every live
    ``dot_general`` / ``conv_general_dilated`` counted exactly; ``scan``
    bodies multiply by the trip count, ``pallas_call`` kernels by the
    grid size, and ``cond`` contributes ``min``/``max`` over its
    branches (a ``pl.when`` inside a kernel lowers to ``cond``, so
    masked grid steps naturally widen the interval instead of guessing).
  * **census** for the lint passes: per-contraction operand dtypes (the
    bf16-region leak check), ``convert_element_type`` records, host
    callback sightings, dead equations and the contraction FLOPs buried
    in them.

Liveness is computed per jaxpr by a reverse sweep from the live outputs
(an equation is live iff any output is demanded or it has effects), so
counting a backward-only program automatically excludes the dead forward
half that ``jax.vjp`` drags along — and the same sweep is the dead-code
lint.

Conv FLOPs convention (matches ``core/flops.py``'s analytic tables):
per spatial dim the MAC pair count is ``O_i * K_i`` — output size times
filter taps — except when ``lhs_dilation > 1`` (a strided conv's dX
VJP), where the real work is ``L_i * K_i`` over the *undilated* operand
rows; counting the dilated output would bill the inserted zeros as
MACs. Total MACs = ``batch * C_out * (C_in / feature_groups) * prod(pairs)``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

from jax import core as jcore

#: primitives that move data or control to the host from inside a
#: jitted program — forbidden in audited train/serve steps.
_CALLBACK_PRIMS = frozenset(
    {"outside_call", "host_callback", "infeed", "outfeed"}
)

_CONTRACTIONS = frozenset({"dot_general", "conv_general_dilated"})


@dataclasses.dataclass(frozen=True)
class Contraction:
    """One live matmul/conv with its launch context."""

    prim: str
    operand_dtypes: tuple[str, ...]
    out_dtype: str
    flops: int          # single-execution cost
    mult: int           # grid/scan multiplier at this program point
    in_cond: bool       # under a cond branch (pl.when etc.)
    path: str


@dataclasses.dataclass(frozen=True)
class Convert:
    src: str
    dst: str
    path: str


@dataclasses.dataclass
class Counts:
    """Everything the walker measures about one program."""

    flops_lo: int = 0
    flops_hi: int = 0
    dead_flops: int = 0
    dead_eqns: int = 0
    unbounded_loops: int = 0
    contractions: list[Contraction] = dataclasses.field(default_factory=list)
    converts: list[Convert] = dataclasses.field(default_factory=list)
    callbacks: list[str] = dataclasses.field(default_factory=list)

    def _absorb(self, child: "Counts", mult_lo: int, mult_hi: int) -> None:
        self.flops_lo += mult_lo * child.flops_lo
        self.flops_hi += mult_hi * child.flops_hi
        self.dead_flops += max(mult_lo, mult_hi) * child.dead_flops
        self.dead_eqns += child.dead_eqns
        self.unbounded_loops += child.unbounded_loops
        self.contractions.extend(child.contractions)
        self.converts.extend(child.converts)
        self.callbacks.extend(child.callbacks)


def _aval(v) -> Any:
    return getattr(v, "aval", None)


def dot_general_flops(eqn) -> int:
    """2 * |out| * contracted extent (batch dims live in |out|)."""
    (lhs_contract, _), _ = eqn.params["dimension_numbers"]
    lhs_shape = _aval(eqn.invars[0]).shape
    out_shape = _aval(eqn.outvars[0]).shape
    contracted = math.prod(lhs_shape[d] for d in lhs_contract)
    return 2 * math.prod(out_shape) * contracted


def conv_flops(eqn) -> int:
    """Dilation-aware conv MACs*2 (see module docstring)."""
    dn = eqn.params["dimension_numbers"]
    lhs_shape = _aval(eqn.invars[0]).shape
    rhs_shape = _aval(eqn.invars[1]).shape
    out_shape = _aval(eqn.outvars[0]).shape
    spatial = len(dn.lhs_spec) - 2
    lhs_dil = eqn.params.get("lhs_dilation") or (1,) * spatial
    fgc = eqn.params.get("feature_group_count", 1)
    del fgc  # rhs input-feature dim is already per-group
    pairs = 1
    for i in range(spatial):
        k_i = rhs_shape[dn.rhs_spec[2 + i]]
        if lhs_dil[i] > 1:
            o_i = lhs_shape[dn.lhs_spec[2 + i]]
        else:
            o_i = out_shape[dn.out_spec[2 + i]]
        pairs *= o_i * k_i
    out_batch = out_shape[dn.out_spec[0]]
    c_out = out_shape[dn.out_spec[1]]
    cin_per_group = rhs_shape[dn.rhs_spec[1]]
    return 2 * out_batch * c_out * cin_per_group * pairs


def _contraction_flops(eqn) -> int:
    if eqn.primitive.name == "dot_general":
        return dot_general_flops(eqn)
    return conv_flops(eqn)


def _grid_size(eqn) -> int:
    grid = eqn.params["grid_mapping"].grid
    return math.prod(int(g) for g in grid) if grid else 1


def _sub_jaxprs(params) -> list:
    """Generic sub-jaxpr discovery for call-like primitives.

    Returns at most one jaxpr: ``jaxpr`` / ``call_jaxpr`` / ``fun_jaxpr``
    on a call-like primitive name the *same* program, so recursing into
    more than one would double-count.
    """
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        j = params.get(key)
        if isinstance(j, jcore.Jaxpr | jcore.ClosedJaxpr):
            return [j]
    return []


def _open(j) -> jcore.Jaxpr:
    return j.jaxpr if isinstance(j, jcore.ClosedJaxpr) else j


def _walk(
    jaxpr: jcore.Jaxpr,
    live_outs: list[bool] | None,
    *,
    in_cond: bool,
    path: str,
) -> Counts:
    """Count one (open) jaxpr. ``live_outs[i]`` says whether outvar i is
    demanded by the caller; ``None`` means all-live (pallas kernels,
    loop bodies — where per-output liveness can't be propagated safely).
    """
    counts = Counts()

    live: set = set()
    outvars = jaxpr.outvars
    if live_outs is None:
        live_outs = [True] * len(outvars)
    for v, is_live in zip(outvars, live_outs, strict=True):
        if is_live and isinstance(v, jcore.Var):
            live.add(v)

    for eqn in reversed(jaxpr.eqns):
        prim = eqn.primitive.name
        eqn_live = (
            live_outs is None
            or bool(eqn.effects)
            or any(isinstance(v, jcore.Var) and v in live for v in eqn.outvars)
        )
        here = f"{path}/{prim}" if path else prim

        if not eqn_live:
            counts.dead_eqns += 1
            if prim in _CONTRACTIONS:
                counts.dead_flops += _contraction_flops(eqn)
            # dead sub-programs contribute nothing; don't recurse
            continue

        for v in eqn.invars:
            if isinstance(v, jcore.Var):
                live.add(v)

        if prim in _CONTRACTIONS:
            flops = _contraction_flops(eqn)
            counts.flops_lo += flops
            counts.flops_hi += flops
            counts.contractions.append(
                Contraction(
                    prim=prim,
                    operand_dtypes=tuple(
                        str(_aval(v).dtype) for v in eqn.invars[:2]
                    ),
                    out_dtype=str(_aval(eqn.outvars[0]).dtype),
                    flops=flops,
                    mult=1,
                    in_cond=in_cond,
                    path=here,
                )
            )
        elif prim == "convert_element_type":
            counts.converts.append(
                Convert(
                    src=str(_aval(eqn.invars[0]).dtype),
                    dst=str(eqn.params["new_dtype"]),
                    path=here,
                )
            )
        elif "callback" in prim or prim in _CALLBACK_PRIMS:
            counts.callbacks.append(here)
        elif prim == "cond":
            branches = eqn.params["branches"]
            kids = [
                _walk(_open(b), [True] * len(eqn.outvars),
                      in_cond=True, path=f"{here}[{i}]")
                for i, b in enumerate(branches)
            ]
            lo = min(k.flops_lo for k in kids)
            hi = max(k.flops_hi for k in kids)
            counts.flops_lo += lo
            counts.flops_hi += hi
            for k in kids:
                counts.dead_flops += k.dead_flops
                counts.dead_eqns += k.dead_eqns
                counts.unbounded_loops += k.unbounded_loops
                counts.contractions.extend(k.contractions)
                counts.converts.extend(k.converts)
                counts.callbacks.extend(k.callbacks)
        elif prim == "scan":
            length = int(eqn.params["length"])
            kid = _walk(_open(eqn.params["jaxpr"]), None,
                        in_cond=in_cond, path=f"{here}x{length}")
            kid.contractions = [
                dataclasses.replace(c, mult=c.mult * length)
                for c in kid.contractions
            ]
            counts._absorb(kid, length, length)
        elif prim == "while":
            counts.unbounded_loops += 1
            for j, tag in ((eqn.params["cond_jaxpr"], "cond"),
                           (eqn.params["body_jaxpr"], "body")):
                kid = _walk(_open(j), None, in_cond=in_cond,
                            path=f"{here}.{tag}")
                counts._absorb(kid, 1, 1)
        elif prim == "pallas_call":
            gsize = _grid_size(eqn)
            kid = _walk(_open(eqn.params["jaxpr"]), None,
                        in_cond=in_cond, path=f"{here}x{gsize}")
            kid.contractions = [
                dataclasses.replace(c, mult=c.mult * gsize)
                for c in kid.contractions
            ]
            counts._absorb(kid, gsize, gsize)
        else:
            subs = _sub_jaxprs(eqn.params)
            for j in subs:
                opened = _open(j)
                if len(opened.outvars) == len(eqn.outvars):
                    sub_live = [
                        isinstance(v, jcore.Var) and v in live
                        or not isinstance(v, jcore.Var)
                        for v in eqn.outvars
                    ]
                else:
                    sub_live = None
                kid = _walk(opened, sub_live, in_cond=in_cond, path=here)
                counts._absorb(kid, 1, 1)

    return counts


def count(closed: jcore.ClosedJaxpr, *, name: str = "") -> Counts:
    """Measure a ClosedJaxpr (all outputs live)."""
    return _walk(closed.jaxpr, [True] * len(closed.jaxpr.outvars),
                 in_cond=False, path=name)
