"""Deterministic synthetic data pipelines.

Real datasets (MNIST…ImageNet-1k) are not available offline, so the data
layer generates deterministic synthetic batches with the right shapes and
*learnable structure* (labels are a function of the input, so training
loss decreases and ssProp-vs-dense comparisons are meaningful). The
pipeline is stateless-by-step: ``batch_at(step)`` is a pure function of
(seed, step), which makes checkpoint/restart and elastic resharding
trivial — a restored job regenerates exactly the batches it would have
seen.

Per-host sharding: each process materializes only its slice of the global
batch (``host_slice``), matching multi-host jax.Array construction.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_classes: int = 0  # unused for LM


class TokenPipeline:
    """Synthetic LM corpus: order-2 Markov stream with a fixed random
    transition structure — has real next-token signal (loss can drop well
    below log(V))."""

    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # sparse-ish transition: each (prev) maps to 8 likely tokens
        self._succ = rng.integers(0, cfg.vocab, size=(min(cfg.vocab, 4096), 8))

    def batch_at(self, step: int, *, host_slice: tuple[int, int] | None = None) -> dict[str, np.ndarray]:
        cfg = self.cfg
        lo, hi = host_slice or (0, cfg.global_batch)
        rng = np.random.default_rng((cfg.seed, step))
        toks = np.empty((cfg.global_batch, cfg.seq_len + 1), np.int32)
        cur = rng.integers(0, cfg.vocab, size=cfg.global_batch)
        toks[:, 0] = cur
        noise = rng.random((cfg.global_batch, cfg.seq_len))
        pick = rng.integers(0, 8, size=(cfg.global_batch, cfg.seq_len))
        for t in range(cfg.seq_len):
            nxt = self._succ[toks[:, t] % self._succ.shape[0], pick[:, t]]
            rand = rng.integers(0, cfg.vocab, size=cfg.global_batch)
            toks[:, t + 1] = np.where(noise[:, t] < 0.1, rand, nxt)
        sl = toks[lo:hi]
        return {"tokens": sl[:, :-1], "targets": sl[:, 1:]}


@dataclasses.dataclass(frozen=True)
class ImagePipelineConfig:
    image: tuple[int, int, int]  # (C, H, W)
    n_classes: int
    global_batch: int
    seed: int = 0


class ImagePipeline:
    """Synthetic classification set: class-conditional Gaussian blobs +
    noise, mimicking the paper's CIFAR/MNIST setups. Fixed finite 'train
    set' so over-fitting dynamics (paper Q1) are observable."""

    def __init__(self, cfg: ImagePipelineConfig, n_train: int = 4096):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        c, h, w = cfg.image
        self._protos = rng.normal(0, 1, size=(cfg.n_classes, c, h, w)).astype(np.float32)
        self._labels = rng.integers(0, cfg.n_classes, size=n_train).astype(np.int32)
        self._noise_seed = rng.integers(0, 2**31)
        self.n_train = n_train

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((self._noise_seed, step))
        idx = rng.integers(0, self.n_train, size=cfg.global_batch)
        y = self._labels[idx]
        # fixed per-example noise (so the set is finite & memorizable)
        ex_rng = np.random.default_rng(42)
        noise_bank = ex_rng.normal(0, 0.5, size=(256,) + cfg.image).astype(np.float32)
        x = self._protos[y] + noise_bank[idx % 256]
        return {"images": x, "labels": y}

    def eval_batch(self, n: int = 256) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(999)
        y = rng.integers(0, cfg.n_classes, size=n).astype(np.int32)
        x = self._protos[y] + rng.normal(0, 0.5, size=(n,) + cfg.image).astype(np.float32)
        return {"images": x.astype(np.float32), "labels": y}


def input_specs(cfg, shape, *, dtype=jnp.int32):
    """ShapeDtypeStruct stand-ins for every model input of one cell.

    Used by the dry-run: weak-type-correct, shardable, no allocation.
    ``cfg`` is a ModelConfig, ``shape`` a ShapeConfig.
    """
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    else:
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "targets": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
    mdt = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["patches"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), mdt)
    if cfg.family == "encdec" and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model), mdt)
    return specs
