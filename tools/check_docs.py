#!/usr/bin/env python
"""Doc-consistency gate: fail CI when README/docs drift from the tree.

Checks, over ``README.md`` and every ``docs/*.md`` page:

1. **Paths exist** — every path-like token in inline code spans (e.g.
   ``src/repro/serve/cache.py``, ``launch/steps.py::make_slot_step``,
   bare well-known filenames like ``test_serve.py``) must resolve
   against the repo root, ``src/`` or ``src/repro/``; bare filenames may
   live anywhere in the tree. Generated artifacts (``BENCH_*.json``)
   are exempt.
2. **Links resolve** — relative markdown links must point at existing
   files.
3. **Snippets import** — every fenced ``python`` block must compile,
   and its top-level imports must resolve (AST-walked, so multi-line
   parenthesized imports work; ``from`` imports also verify the name
   exists on the module) with ``src/`` on ``sys.path`` — a renamed
   module or symbol breaks the build, not the reader.
4. **CLI flags exist** — every ``python <script> --flag ...`` command
   (fenced or inline, backslash continuations joined) is checked
   against the script's actual argparse surface, read statically from
   its source (every ``add_argument("--...")`` string — no imports, so
   a script with heavy deps still checks). A documented flag that the
   script no longer defines is a failure.

Run:  python tools/check_docs.py        (CI runs it in the ruff lane)
Exit: 0 clean, 1 with a list of stale references.
"""
from __future__ import annotations

import ast
import importlib
from pathlib import Path
import re
import sys

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

DOC_FILES = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
SEARCH_ROOTS = (ROOT, ROOT / "src", ROOT / "src" / "repro")
CHECK_EXTS = (".py", ".md", ".json", ".toml", ".yml", ".yaml", ".txt")
# artifacts produced by running benchmarks — documented but not committed
GENERATED = re.compile(r"^BENCH_.*\.json$")
# ssProp policy-program site names (docs/policies.md) look path-like but
# name model call sites, not files: layer_3/attn/q, block_0/conv1,
# moe/shared/up, enc/layer_0/mlp/down, ...
SITE_NAME = re.compile(
    r"^(enc/)?(layer|block)_\d+/"
    r"|^(stem|out|mid\d|down\d|up\d)/"
    r"|^(attn|self|cross|mlp|moe|ssm)/"
)

INLINE_CODE = re.compile(r"`([^`\n]+)`")
MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE = re.compile(r"^```(\w*)\s*$")
PATHY = re.compile(r"^[\w./\-]+$")


def iter_path_tokens(text: str):
    """Path-like strings inside inline code spans."""
    for tok in INLINE_CODE.findall(text):
        tok = tok.split("::")[0].strip()  # `pkg/mod.py::fn` -> pkg/mod.py
        if not PATHY.match(tok) or tok.startswith("--"):
            continue
        name = tok.rstrip("/").rsplit("/", 1)[-1]
        if "/" in tok or name.endswith(CHECK_EXTS):
            yield tok.rstrip("/")


def resolve(tok: str) -> bool:
    if GENERATED.match(tok.rsplit("/", 1)[-1]):
        return True
    if SITE_NAME.match(tok):
        return True
    for root in SEARCH_ROOTS:
        if (root / tok).exists():
            return True
    if "/" not in tok:  # bare filename: anywhere in the tree
        return any(ROOT.rglob(tok))
    return False


def python_snippets(text: str):
    """Yield the bodies of fenced ```python blocks."""
    lines = text.splitlines()
    body, lang = [], None
    for line in lines:
        m = FENCE.match(line)
        if m:
            if lang is None:
                lang, body = m.group(1), []
            else:
                if lang == "python":
                    yield "\n".join(body)
                lang = None
            continue
        if lang is not None:
            body.append(line)


def check_snippet(src: str):
    """Compile the snippet; resolve its top-level imports (AST-based, so
    multi-line parenthesized imports work) and verify imported names
    exist on their modules."""
    tree = ast.parse(src, "<doc-snippet>")  # SyntaxError propagates
    for node in tree.body:
        if isinstance(node, ast.Import):
            for alias in node.names:
                importlib.import_module(alias.name)
        elif isinstance(node, ast.ImportFrom) and node.level == 0 and node.module:
            try:
                mod = importlib.import_module(node.module)
            except ImportError:
                mod = None
            for alias in node.names:
                if alias.name == "*":
                    continue
                if mod is not None and hasattr(mod, alias.name):
                    continue
                # `from pkg import submodule` with no attribute
                importlib.import_module(f"{node.module}.{alias.name}")


# ----------------------------------------------------------------------
# CLI flag cross-check
# ----------------------------------------------------------------------

# `... \` + newline (+ optional `$ ` console prompt) -> one command line
CONT = re.compile(r"\\\n\s*(?:\$\s+)?")
PY_CMD = re.compile(r"python3?\s+(-m\s+[\w.]+|[\w./\-]+\.py)([^\n`]*)")
FLAG = re.compile(r"--[A-Za-z0-9][\w-]*")

_FLAG_CACHE: dict[Path, set[str] | None] = {}


def argparse_flags(script: Path) -> set[str] | None:
    """Every ``--flag`` the script defines, read from source (no import).

    Walks the AST for ``*.add_argument("--...")`` calls; returns None
    when the script defines no argparse surface at all (then any
    documented flag is stale by definition).
    """
    if script not in _FLAG_CACHE:
        tree = ast.parse(script.read_text(encoding="utf-8"))
        flags: set[str] = set()
        seen_parser = False
        for node in ast.walk(tree):
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument"
            ):
                seen_parser = True
                for arg in node.args:
                    if (
                        isinstance(arg, ast.Constant)
                        and isinstance(arg.value, str)
                        and arg.value.startswith("--")
                    ):
                        flags.add(arg.value)
        _FLAG_CACHE[script] = flags if seen_parser else None
    return _FLAG_CACHE[script]


def _resolve_script(target: str) -> Path | None | str:
    """Map a command target to a repo script path.

    Returns a Path, ``None`` for out-of-repo targets (``-m pytest``),
    or the stale target string when it should exist but doesn't.
    """
    if target.startswith("-m"):
        module = target.split()[-1]
        top = module.split(".")[0]
        if top == "repro":
            p = ROOT / "src" / (module.replace(".", "/") + ".py")
        elif top in ("benchmarks", "tools"):
            p = ROOT / (module.replace(".", "/") + ".py")
        else:
            return None  # pytest, pip, ... not ours
        return p if p.exists() else target
    for root in (ROOT, ROOT / "src"):
        if (root / target).exists():
            return root / target
    return target


def check_cli_flags(text: str, rel) -> list[str]:
    """Cross-check every documented python command's flags."""
    failures = []
    for target, tail in PY_CMD.findall(CONT.sub(" ", text)):
        script = _resolve_script(target.strip())
        if script is None:
            continue
        if isinstance(script, str):
            failures.append(f"{rel}: command references missing `{script}`")
            continue
        used = {f.split("=")[0] for f in FLAG.findall(tail)}
        known = argparse_flags(script)
        for flag in sorted(used - (known or set())):
            failures.append(
                f"{rel}: `{script.relative_to(ROOT)}` defines no `{flag}`"
            )
    return failures


def main() -> int:
    failures = []
    for doc in DOC_FILES:
        if not doc.exists():
            failures.append(f"{doc.relative_to(ROOT)}: file missing")
            continue
        text = doc.read_text(encoding="utf-8")
        rel = doc.relative_to(ROOT)

        for tok in iter_path_tokens(text):
            if not resolve(tok):
                failures.append(f"{rel}: stale path `{tok}`")

        for link in MD_LINK.findall(text):
            if link.startswith(("http://", "https://", "mailto:")):
                continue
            link = link.split("#")[0]  # drop the anchor, keep the file
            if not link:
                continue  # same-page anchor
            if not ((doc.parent / link).exists() or (ROOT / link).exists()):
                failures.append(f"{rel}: broken link ({link})")

        failures.extend(check_cli_flags(text, rel))

        for i, snip in enumerate(python_snippets(text)):
            try:
                check_snippet(snip)
            except Exception as e:  # noqa: BLE001 — report, don't crash
                failures.append(
                    f"{rel}: python snippet #{i + 1} failed: {type(e).__name__}: {e}"
                )

    if failures:
        print(f"check_docs: {len(failures)} stale reference(s)")
        for f in failures:
            print(f"  - {f}")
        return 1
    n = len(DOC_FILES)
    print(f"check_docs: OK ({n} files checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
