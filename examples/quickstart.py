"""Quickstart: drop ssProp into any model in ~20 lines.

The paper's pitch is a drop-in efficient module: replace your matmul /
conv call with ``sparse_dense`` / ``sparse_conv2d`` and drive the drop
rate with a scheduler. This script trains a 2-layer MLP on synthetic
data twice — dense vs ssProp(bar-80%) — and prints the loss curves and
the backward-FLOPs saving.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import SsPropPolicy, sparse_dense, flops
from repro.core.policy import paper_default
from repro.core.schedulers import drop_rate_for_step


def init(rng, d_in=64, d_h=256, d_out=10):
    k1, k2 = jax.random.split(rng)
    return {
        "w1": jax.random.normal(k1, (d_in, d_h)) * 0.05,
        "b1": jnp.zeros((d_h,)),
        "w2": jax.random.normal(k2, (d_h, d_out)) * 0.05,
        "b2": jnp.zeros((d_out,)),
    }


def forward(params, x, policy):
    h = jax.nn.relu(sparse_dense(x, params["w1"], params["b1"], policy=policy))
    return sparse_dense(h, params["w2"], params["b2"], policy=policy)


def train(policy_for_step, steps=200, seed=0):
    rng = jax.random.PRNGKey(seed)
    params = init(rng)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (512, 64))
    y = (x[:, 0] > 0).astype(jnp.int32) + 2 * (x[:, 1] > 0).astype(jnp.int32)

    def loss_fn(p, pol):
        logits = forward(p, x, pol)
        return -jax.nn.log_softmax(logits)[jnp.arange(512), y].mean()

    steps_fns = {}

    def step_fn(pol):
        if pol.drop_rate not in steps_fns:
            @jax.jit
            def f(p):
                lv, g = jax.value_and_grad(loss_fn)(p, pol)
                return jax.tree.map(lambda a, b: a - 0.1 * b, p, g), lv
            steps_fns[pol.drop_rate] = f
        return steps_fns[pol.drop_rate]

    hist = []
    for i in range(steps):
        pol = policy_for_step(i)
        params, loss = step_fn(pol)(params)
        hist.append(float(loss))
    return hist


def main():
    dense_hist = train(lambda i: SsPropPolicy(0.0))
    bar = lambda i: paper_default(0.8).bucketed(
        drop_rate_for_step("epoch_bar", step=i, steps_per_epoch=20,
                           total_steps=200, target=0.8)
    )
    ssprop_hist = train(bar)

    print("step   dense-loss  ssprop-loss")
    for i in range(0, 200, 25):
        print(f"{i:5d}   {dense_hist[i]:9.4f}  {ssprop_hist[i]:10.4f}")
    print(f"final  {dense_hist[-1]:9.4f}  {ssprop_hist[-1]:10.4f}")

    d = flops.dense_backward_flops(512, 64, 256) + flops.dense_backward_flops(512, 256, 10)
    s = flops.dense_backward_flops_ssprop(512, 64, 256, 0.4) + \
        flops.dense_backward_flops_ssprop(512, 256, 10, 0.4)
    print(f"\nbackward FLOPs/iter: dense {d:,} -> ssprop(avg 40%) {s:,} "
          f"({100 * (1 - s / d):.1f}% saved)")


if __name__ == "__main__":
    main()
