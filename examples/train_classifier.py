"""End-to-end driver: train a ResNet classifier for a few hundred steps,
dense vs ssProp (2-epoch bar @ 80%), reproducing the paper's protocol at
laptop scale: same optimizer (Adam 2e-4), Kaiming init, no augmentation.

Prints per-epoch train loss / eval accuracy for both modes plus the
backward-FLOPs ledger. ~100M-param variant available via --model
resnet50 --image-size 32.

Run:  PYTHONPATH=src python examples/train_classifier.py --steps 300
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core.policy import SsPropPolicy, paper_default
from repro.core.schedulers import average_rate, drop_rate_for_step
from repro.data.pipeline import ImagePipeline, ImagePipelineConfig
from repro.models import resnet
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="resnet18", choices=list(resnet.LAYOUTS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--steps-per-epoch", type=int, default=50)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--image-size", type=int, default=16)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--lr", type=float, default=2e-4)  # paper Table 2
    ap.add_argument("--drop-rate", type=float, default=0.8)
    args = ap.parse_args()

    image = (3, args.image_size, args.image_size)
    pipe = ImagePipeline(
        ImagePipelineConfig(image, args.classes, args.batch, seed=11), n_train=1024
    )
    ocfg = adam.AdamConfig(lr=args.lr)

    def build(policy_rate_fn, seed=0):
        params = resnet.init_params(args.model, jax.random.PRNGKey(seed), args.classes)
        opt = adam.init(params)
        jits = {}

        def loss_fn(p, x, y, pol):
            logits = resnet.forward(args.model, p, x, pol)
            return -jax.nn.log_softmax(logits)[jnp.arange(x.shape[0]), y].mean()

        def get(rate):
            if rate not in jits:
                pol = paper_default(rate) if rate > 0 else SsPropPolicy(0.0)

                @jax.jit
                def f(p, o, x, y):
                    lv, g = jax.value_and_grad(loss_fn)(p, x, y, pol)
                    p2, o2, _ = adam.apply_updates(ocfg, p, g, o)
                    return p2, o2, lv

                jits[rate] = f
            return jits[rate]

        return params, opt, get

    results = {}
    for mode in ("dense", "ssprop"):
        rate_fn = (
            (lambda i: 0.0)
            if mode == "dense"
            else lambda i: drop_rate_for_step(
                "epoch_bar", step=i, steps_per_epoch=args.steps_per_epoch,
                total_steps=args.steps, target=args.drop_rate,
            )
        )
        params, opt, get = build(rate_fn)
        t0 = time.time()
        for i in range(args.steps):
            b = jax.tree.map(jnp.asarray, pipe.batch_at(i))
            params, opt, loss = get(rate_fn(i))(params, opt, b["images"], b["labels"])
            if (i + 1) % args.steps_per_epoch == 0:
                ev = pipe.eval_batch(256)
                logits = resnet.forward(
                    args.model, params, jnp.asarray(ev["images"]),
                    SsPropPolicy(0.0), train=False,
                )
                acc = float((jnp.argmax(logits, -1) == jnp.asarray(ev["labels"])).mean())
                print(f"[{mode}] step {i+1:4d} loss={float(loss):.4f} eval_acc={acc:.3f}")
        results[mode] = (time.time() - t0, acc)

    avg = average_rate(
        "epoch_bar", total_steps=args.steps,
        steps_per_epoch=args.steps_per_epoch, target=args.drop_rate,
    )
    d, _ = resnet.flops_per_iter(args.model, args.batch, image)
    _, s = resnet.flops_per_iter(args.model, args.batch, image, avg)
    print(f"\nbackward FLOPs/iter: dense {d/1e9:.2f}B -> ssprop {s/1e9:.2f}B "
          f"({100*(1-s/d):.1f}% saved at schedule-average rate {avg:.2f})")
    for mode, (t, acc) in results.items():
        print(f"{mode:7s} wall={t:.1f}s final_eval_acc={acc:.3f}")


if __name__ == "__main__":
    main()
