"""Generation example: train a DDPM UNet with ssProp, then sample.

Reduced-scale version of the paper's Table 5 protocol: AdamW, epsilon
MSE, linear beta schedule, 2-epoch bar sparsity at 80%. Prints the loss
curve for dense vs ssProp and writes a grid of sampled images (as .npy).

Run:  PYTHONPATH=src python examples/ddpm_generation.py --steps 200
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import SsPropPolicy, paper_default
from repro.core.schedulers import drop_rate_for_step
from repro.models import ddpm
from repro.optim import adam


def synth_images(step, batch, size):
    """Deterministic 'dataset': gaussian blobs at class-dependent spots."""
    rng = np.random.default_rng((123, step))
    xs = np.zeros((batch, 1, size, size), np.float32)
    for i in range(batch):
        cx, cy = rng.integers(size // 4, 3 * size // 4, 2)
        yy, xx = np.mgrid[0:size, 0:size]
        xs[i, 0] = np.exp(-((xx - cx) ** 2 + (yy - cy) ** 2) / 8.0)
    return jnp.asarray(xs * 2 - 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--steps-per-epoch", type=int, default=40)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--size", type=int, default=16)
    ap.add_argument("--timesteps", type=int, default=100)
    ap.add_argument("--out", default="/tmp/ddpm_samples.npy")
    args = ap.parse_args()

    sched = ddpm.make_schedule(args.timesteps)
    ocfg = adam.adamw()

    for mode in ("dense", "ssprop"):
        params = ddpm.init_params(jax.random.PRNGKey(0), channels=1, base=16, t_dim=64)
        opt = adam.init(params)
        jits = {}

        def get(rate):
            if rate not in jits:
                pol = paper_default(rate) if rate > 0 else SsPropPolicy(0.0)

                @jax.jit
                def f(p, o, x, rng):
                    lv, g = jax.value_and_grad(
                        lambda p: ddpm.loss_fn(p, sched, x, rng, pol)
                    )(p)
                    p2, o2, _ = adam.apply_updates(ocfg, p, g, o)
                    return p2, o2, lv

                jits[rate] = f
            return jits[rate]

        rng = jax.random.PRNGKey(1)
        for i in range(args.steps):
            rate = 0.0 if mode == "dense" else drop_rate_for_step(
                "epoch_bar", step=i, steps_per_epoch=args.steps_per_epoch,
                total_steps=args.steps, target=0.8,
            )
            x = synth_images(i, args.batch, args.size)
            rng, sub = jax.random.split(rng)
            params, opt, loss = get(rate)(params, opt, x, sub)
            if (i + 1) % args.steps_per_epoch == 0:
                print(f"[{mode}] step {i+1:4d} loss={float(loss):.4f}")

        if mode == "ssprop":
            samples = ddpm.sample(
                params, sched, jax.random.PRNGKey(42), (4, 1, args.size, args.size)
            )
            np.save(args.out, np.asarray(samples))
            print(f"[ssprop] wrote {args.out} "
                  f"(range [{float(samples.min()):.2f}, {float(samples.max()):.2f}])")


if __name__ == "__main__":
    main()
