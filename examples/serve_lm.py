"""Serving example: batched autoregressive generation for any --arch.

Thin wrapper over the production serving driver (repro.launch.serve):
prefill a prompt batch, decode with the jitted single-token step, report
throughput. Works for every assigned architecture (reduced configs on
CPU), including the SSM/hybrid O(1)-state decoders.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch jamba-1.5-large-398b
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import build_parser, run


def main():
    ap = build_parser()
    ap.set_defaults(reduced=True, batch=4, prompt_len=8, gen=16)
    args = ap.parse_args()
    out = run(args)
    print(f"[serve_lm] arch={args.arch} batch={args.batch}")
    print(f"[serve_lm] prefill {out['prefill_s']*1e3:.0f} ms, "
          f"decode {out['decode_s']*1e3:.0f} ms ({out['tokens_per_s']:.1f} tok/s)")
    for i, row in enumerate(out["generated"][:2]):
        print(f"[serve_lm] request {i}: {row[:12].tolist()}")


if __name__ == "__main__":
    main()
