"""Serving example: continuous batching under staggered (Poisson) traffic.

Drives the slot-based engine for any --arch (reduced configs on CPU,
all families incl. the SSM/hybrid O(1)-state decoders and the Whisper
encoder-decoder): requests arrive staggered, join the batch as slots
free up, prefill in chunks interleaved with running decodes, and leave
on completion. Compare with the static baseline via --engine lockstep,
or run the paged KV cache via --engine paged --block-size 8 (add
--n-blocks to shrink the pool below worst case and watch preemptions).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch jamba-1.5-large-398b
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import build_parser, run


def main():
    ap = build_parser()
    ap.set_defaults(
        reduced=True, batch=4, prompt_len=8, gen=16, requests=8,
        arrival_rate=0.5, prefill_chunk=4,
    )
    args = ap.parse_args()
    out = run(args)
    print(f"[serve_lm] arch={args.arch} engine={args.engine} "
          f"slots={args.batch} requests={args.requests or args.batch}")
    print(f"[serve_lm] {out['steps']} steps, prefill {out['prefill_s']*1e3:.0f} ms, "
          f"decode {out['decode_s']*1e3:.0f} ms ({out['tokens_per_s']:.1f} tok/s, "
          f"slot util {out['slot_utilization']*100:.0f}%)")
    for i, row in enumerate(out["generated"][:2]):
        print(f"[serve_lm] request {i}: {row[:12].tolist()}")


if __name__ == "__main__":
    main()
